"""Fit the analytic cost model's coefficients against BENCH history.

Every ``BENCH_network.json`` ladder row (network × method × fused/
unfused) becomes one calibration point: its plan is recompiled exactly
as the bench ran it, ``repro.core.cost`` extracts the aggregate features
(per-bucket GFLOPs, HBM GB streamed, dispatch count), and the measured
``us_per_call`` is the target.  A deterministic fit/holdout split
(points sorted by id, every ``--holdout-every``-th held out) keeps the
reported rank correlation honest: ``spearman_holdout`` is computed on
points the solver never saw.  Serving rows (``cnn_server``) are queue
latencies, not per-call kernel time — they are not calibration points.

The fitted coefficients land in ``COST_MODEL.json`` under their backend
key (other backends' entries are preserved on re-fit), which
``tools/autotune.py`` and ``tools/cost_validate.py`` consume:

    PYTHONPATH=src python -m benchmarks.cost_fit BENCH_network.json \
        --out COST_MODEL.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Mapping, Tuple

from repro.core.cost import (CostModel, fit_coefficients, fused_flop_key,
                             plan_cost, spearman)
from repro.core.methods import Method
from repro.core.netdefs import NETWORKS
from repro.core.plan import compile_plan

COST_MODEL_FORMAT_VERSION = 1


def bench_backend(bench: Mapping) -> Tuple[str, bool]:
    """The bench's backend name and whether its plans ran Pallas."""
    backend = bench.get("backend", "cpu")
    return backend, backend not in ("cpu",)


def ladder_points(bench: Mapping) -> List[Dict]:
    """One calibration point per measured ladder row-variant, features
    extracted from the SAME plan configuration the bench executed."""
    batch = int(bench["batch"])
    _, use_pallas = bench_backend(bench)
    pts: List[Dict] = []
    for net_name in sorted(bench["networks"]):
        net = NETWORKS[net_name]()
        for row in bench["networks"][net_name]["rows"]:
            method = Method(row["method"])
            for variant, fuse in (("unfused", False), ("fused", True)):
                r = row.get(variant)
                if not r:
                    continue
                plan = compile_plan(net, method=method, fuse=fuse,
                                    use_pallas=use_pallas, verify=False)
                pc = plan_cost(plan, batch=batch)
                pts.append({
                    "id": f"{net_name}/{method.value}/{variant}",
                    # the per-step buckets plan_cost prices (what the
                    # validator and the committed-model rho see)
                    "flops_by_key": pc.flops_by_key,
                    # the solver's view: the row's TOTAL flops under the
                    # row's method(:fused) bucket.  A whole-ladder row
                    # ran every layer under one method; giving fc its
                    # own column makes it collinear with the method
                    # columns and the solver prunes it into nonsense —
                    # collapsing is the attribution that actually ranks
                    # (the fc coefficient is pinned post-fit instead)
                    "fit_flops_by_key": {
                        fused_flop_key(method) if fuse else method.value:
                        pc.flops},
                    "hbm_bytes": pc.hbm_bytes,
                    "dispatches": pc.dispatches,
                    "us": float(r["us_per_call"]),
                })
    return pts


def split_points(pts: List[Dict],
                 holdout_every: int = 3) -> Tuple[List[Dict], List[Dict]]:
    """Deterministic fit/holdout split: sorted by id, every
    ``holdout_every``-th point held out (0 disables the holdout)."""
    pts = sorted(pts, key=lambda p: p["id"])
    if holdout_every <= 0:
        return pts, []
    fit, hold = [], []
    for i, p in enumerate(pts):
        (hold if i % holdout_every == holdout_every - 1 else fit).append(p)
    return fit, hold


def _rho(model: CostModel, pts: List[Dict]) -> float:
    pred = [model.predict(p["flops_by_key"], p["hbm_bytes"],
                          p["dispatches"]) for p in pts]
    return spearman(pred, [p["us"] for p in pts])


def fit_model(bench: Mapping, holdout_every: int = 3) -> Tuple[CostModel,
                                                               Dict]:
    """Fit on the split's fit points; validate rank fidelity on the fit
    set, the holdout set, and all points.  Returns the model plus the
    validation record that ships inside COST_MODEL.json."""
    backend, _ = bench_backend(bench)
    pts = ladder_points(bench)
    fit_pts, hold_pts = split_points(pts, holdout_every)
    model = fit_coefficients(
        [{**p, "flops_by_key": p["fit_flops_by_key"]} for p in fit_pts],
        backend=backend)
    # pin the buckets the collapsed fit cannot see: fc is the same
    # fused-matmul staging as the advanced path (price it there), and
    # the pool/lrn/softmax tail rides with it — both are small slices
    # of any row, but the max-fitted fallback would let them dominate
    coeffs = dict(model.us_per_gflop)
    coeffs["fc"] = coeffs["other"] = coeffs[Method.ADVANCED_SIMD_8.value]
    model = CostModel(backend=model.backend, us_per_gflop=coeffs,
                      us_per_gb=model.us_per_gb,
                      dispatch_us=model.dispatch_us)
    validation = {
        "points": len(pts),
        "fit_points": len(fit_pts),
        "holdout_points": len(hold_pts),
        "holdout_every": holdout_every,
        "spearman_fit": round(_rho(model, fit_pts), 4),
        "spearman_holdout": (round(_rho(model, hold_pts), 4)
                             if len(hold_pts) >= 2 else None),
        "spearman_all": round(_rho(model, pts), 4),
    }
    return model, validation


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench", nargs="?", default="BENCH_network.json",
                    help="measured BENCH_network.json to calibrate against")
    ap.add_argument("--out", default="COST_MODEL.json",
                    help="cost-model file to write (existing entries for "
                         "OTHER backends are preserved)")
    ap.add_argument("--holdout-every", type=int, default=3,
                    help="hold out every N-th point for validation "
                         "(0 = fit on everything)")
    args = ap.parse_args(argv)

    try:
        with open(args.bench) as f:
            bench = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot read bench file {args.bench}: {e}",
              file=sys.stderr)
        return 2

    model, validation = fit_model(bench, args.holdout_every)
    entry = model.to_dict()
    entry["fitted_from"] = {
        "bench": args.bench,
        "nets": sorted(bench["networks"]),
        "batch": bench.get("batch"),
        "iters": bench.get("iters"),
    }
    entry["validation"] = validation

    out_path = Path(args.out)
    data = {"format_version": COST_MODEL_FORMAT_VERSION, "backends": {}}
    if out_path.exists():
        try:
            data = json.loads(out_path.read_text())
        except json.JSONDecodeError:
            print(f"warning: overwriting unreadable {args.out}",
                  file=sys.stderr)
            data = {"format_version": COST_MODEL_FORMAT_VERSION,
                    "backends": {}}
    data.setdefault("backends", {})[model.backend] = entry
    out_path.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")

    print(f"fitted backend={model.backend} from {validation['fit_points']} "
          f"points (holdout {validation['holdout_points']})")
    print(f"  spearman fit={validation['spearman_fit']} "
          f"holdout={validation['spearman_holdout']} "
          f"all={validation['spearman_all']}")
    print(f"  us_per_gflop={ {k: round(v, 1) for k, v in model.us_per_gflop.items()} }")
    print(f"  us_per_gb={model.us_per_gb:.2f} dispatch_us={model.dispatch_us:.2f}")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
