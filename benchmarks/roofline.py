"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch × shape × mesh), all in seconds:

  compute    = HLO_FLOPs_global  / (chips × 197 TFLOP/s bf16)
  memory     = HLO_bytes_global  / (chips × 819 GB/s HBM)
  collective = coll_bytes_global / (chips × 50 GB/s ICI link)

The dry-run records per-device values (the SPMD module), so each term
reduces to per-device / unit-rate.  MODEL_FLOPS uses 6·N·D (training) or
2·N·D (inference) with N = active, non-embedding params; the ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/replication/dispatch waste.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
Writes results/roofline.csv and prints the markdown table.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

_SUGGEST = {
    "compute": ("shard the replicated attention heads (pad to the model-axis "
                "multiple) or cut remat recompute"),
    "memory": ("fuse elementwise chains / widen kernel blocks so each HBM "
               "byte feeds more FLOPs; int8 KV for decode"),
    "collective": ("reduce per-layer all-gathers (FSDP prefetch/reuse), "
                   "overlap collectives with compute, or move the axis with "
                   "the most traffic onto faster links"),
}


def model_flops(cfg, shape) -> float:
    """Useful FLOPs per step: 6·N·D train, 2·N·D forward (N active,
    non-embedding)."""
    from repro.models.registry import analytic_param_count

    n = analytic_param_count(cfg, active_only=True, non_embedding=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per request


def analyze_record(rec: dict) -> dict:
    from repro.core.config import get_arch, get_shape

    cfg = get_arch(rec["arch"])
    shape = get_shape(rec["shape"])
    chips = rec["num_devices"]
    flops_dev = rec["hlo"]["flops"]
    bytes_dev = rec["hlo"]["bytes"]
    coll_dev = rec["hlo"]["collective_bytes_total"]
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_dev / ICI_BW,
    }
    dominant = max(terms, key=terms.get).replace("_s", "")
    mf = model_flops(cfg, shape)
    hlo_global = flops_dev * chips
    out = {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        **terms,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "mem_gib_per_dev": rec["memory"]["per_device_total_bytes"] / 2**30,
        "fits_16gb": rec["memory"]["per_device_total_bytes"] <= 16e9,
        "suggest": _SUGGEST[dominant],
        "step_s_bound": max(terms.values()),
    }
    return out


def load_all(dirpath: Path, mesh: str = None):
    rows = []
    for f in sorted(dirpath.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "error": rec.get("error", "?")})
            continue
        if mesh and rec["mesh"] != mesh:
            continue
        rows.append(analyze_record(rec))
    return rows


def to_markdown(rows) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | coll s | "
           "dominant | useful | GiB/dev | fits |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR: {r['error'][:40]} |||||||")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['mem_gib_per_dev']:.2f} "
            f"| {'Y' if r['fits_16gb'] else 'N'} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--csv", default="results/roofline.csv")
    args = ap.parse_args()
    rows = load_all(Path(args.dir), args.mesh)
    ok = [r for r in rows if "error" not in r]
    print(to_markdown(rows))
    import csv as _csv

    Path(args.csv).parent.mkdir(parents=True, exist_ok=True)
    if ok:
        with open(args.csv, "w", newline="") as f:
            w = _csv.DictWriter(f, fieldnames=list(ok[0].keys()))
            w.writeheader()
            w.writerows(ok)
        print(f"\n[roofline] {len(ok)} rows -> {args.csv}")


if __name__ == "__main__":
    main()
