"""Paper §4 FC acceleration: fused bias+activation matmul vs the unfused
two-pass form — wall time and HLO bytes (the fusion saves one HBM pass)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo_text

SHAPES = [(16, 9216, 4096), (16, 4096, 4096), (16, 4096, 1000)]  # AlexNet FCs


def _unfused(x, w, b):
    y = x @ w
    y = jax.lax.optimization_barrier(y)  # force the extra pass to be real
    y = y + b
    y = jax.lax.optimization_barrier(y)
    return jnp.maximum(y, 0.0)


def _fused(x, w, b):
    return jnp.maximum(x @ w + b, 0.0)


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    for m, k, n in SHAPES:
        x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.02
        b = jnp.ones((n,))
        f_un = jax.jit(_unfused)
        f_fu = jax.jit(_fused)
        us_un = _time(f_un, x, w, b)
        us_fu = _time(f_fu, x, w, b)
        b_un = analyze_hlo_text(f_un.lower(x, w, b).compile().as_text()).bytes
        b_fu = analyze_hlo_text(f_fu.lower(x, w, b).compile().as_text()).bytes
        rows.append({
            "bench": f"fc_fused/{m}x{k}x{n}",
            "us_per_call": us_fu,
            "derived": (f"unfused_us={us_un:.0f} speedup={us_un/us_fu:.2f}x "
                        f"bytes_saved={(b_un-b_fu)/max(b_un,1)*100:.0f}%"),
        })
    return rows
