"""Paper Table 3 analogue: whole-network runtime × execution-method ladder
(+ FPS derived column, §6.3 realtime check)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.engine import CNNEngine
from repro.core.methods import Method, LADDER
from repro.core.netdefs import NETWORKS

BATCH = 16


def _time(fn, *args, iters=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(nets=("lenet5", "cifar10"), batch=BATCH):
    """The paper's CPU baseline is single-threaded Java (no compiler); the
    honest analogue here is *un-jitted* op-by-op dispatch.  Table 3's
    speedup thus decomposes into (compiler/runtime) × (layout/blocking);
    the paper itself attributes the >48x-of-theoretical-peak part of its
    63x to RenderScript-vs-Java language overhead (§6.3)."""
    rows = []
    for name in nets:
        net = NETWORKS[name]()
        eng0 = CNNEngine(net, method=Method.SEQ_REF)
        params = eng0.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (batch, *net.input_shape), jnp.float32)
        # "Java" baseline: sequential method, eager op-by-op dispatch
        base_us = _time(eng0.forward, params, x, iters=1)
        fps = batch / (base_us / 1e6)
        rows.append({
            "bench": f"network_ladder/{name}/cpu_unjitted(java-analogue)",
            "us_per_call": base_us,
            "derived": f"speedup=1.00x fps={fps:.1f}",
        })
        for method in LADDER:
            eng = CNNEngine(net, method=method)
            fn = eng.jit_forward()
            us = _time(fn, params, x)
            fps = batch / (us / 1e6)
            rows.append({
                "bench": f"network_ladder/{name}/{method.value}",
                "us_per_call": us,
                "derived": f"speedup={base_us/us:.2f}x fps={fps:.1f}",
            })
    return rows
