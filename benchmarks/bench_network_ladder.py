"""Paper Table 3 analogue: whole-network runtime × execution-method ladder
(+ FPS derived column, §6.3 realtime check).

Each method row now also reports the fused super-layer forward (the
fusion planner's conv[+relu][+pool] groups) against the unfused jitted
ladder — the ratio the fusion subsystem is accountable for.  ``run_json``
emits the same sweep machine-readable (``BENCH_network.json`` via
``benchmarks/run.py --json``) so the perf trajectory is recorded across
PRs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.engine import CNNEngine
from repro.core.fusion import fusion_summary
from repro.core.methods import Method, LADDER
from repro.core.netdefs import NETWORKS

BATCH = 16


def _time(fn, *args, iters=3):
    """Median wall time per call in us (first call outside the clock)."""
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def run(nets=("lenet5", "cifar10"), batch=BATCH):
    """The paper's CPU baseline is single-threaded Java (no compiler); the
    honest analogue here is *un-jitted* op-by-op dispatch.  Table 3's
    speedup thus decomposes into (compiler/runtime) × (layout/blocking);
    the paper itself attributes the >48x-of-theoretical-peak part of its
    63x to RenderScript-vs-Java language overhead (§6.3)."""
    rows = []
    for name in nets:
        net = NETWORKS[name]()
        eng0 = CNNEngine(net, method=Method.SEQ_REF)
        params = eng0.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (batch, *net.input_shape), jnp.float32)
        # "Java" baseline: sequential method, eager op-by-op dispatch
        base_us = _time(eng0.forward, params, x, iters=1)
        fps = batch / (base_us / 1e6)
        rows.append({
            "bench": f"network_ladder/{name}/cpu_unjitted(java-analogue)",
            "us_per_call": base_us,
            "derived": f"speedup=1.00x fps={fps:.1f}",
        })
        for method in LADDER:
            eng = CNNEngine(net, method=method)
            us = _time(eng.jit_forward(fuse=False), params, x)
            fps = batch / (us / 1e6)
            rows.append({
                "bench": f"network_ladder/{name}/{method.value}",
                "us_per_call": us,
                "derived": f"speedup={base_us/us:.2f}x fps={fps:.1f}",
            })
            if not fusion_summary(eng.plan(True)):
                continue  # no fusable groups for this method (fallback)
            us_f = _time(eng.jit_forward(fuse=True), params, x)
            fps_f = batch / (us_f / 1e6)
            rows.append({
                "bench": f"network_ladder/{name}/{method.value}/fused",
                "us_per_call": us_f,
                "derived": (f"speedup={base_us/us_f:.2f}x fps={fps_f:.1f} "
                            f"fused_vs_unfused={us/us_f:.2f}x"),
            })
    return rows


def run_json(nets=("lenet5", "cifar10"), batch=BATCH, iters=3,
             methods=LADDER):
    """Machine-readable fused-vs-unfused sweep for BENCH_network.json."""
    out = {"bench": "network_ladder", "batch": batch, "iters": iters,
           "backend": jax.default_backend(), "networks": {},
           "note": ("advanced_simd_* fused ratios on the XLA backend fold "
                    "in the super-layer's full-width oc matmul (vs the "
                    "per-layer 4/8-wide blocks); basic_simd fused ratios "
                    "share identical conv math with unfused and isolate "
                    "the fusion win itself; fused_groups ending in a "
                    "norm layer run the conv->relu->pool->LRN tail as "
                    "one dispatch (PR 3 LRN epilogue); fused_groups with "
                    "several convs run the whole chain as one dispatch "
                    "(PR 4 VMEM-resident halo) — fused_geometry records "
                    "each group's depth and the band a Pallas cell "
                    "resolves")}
    for name in nets:
        net = NETWORKS[name]()
        eng0 = CNNEngine(net, method=Method.SEQ_REF)
        params = eng0.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (batch, *net.input_shape), jnp.float32)
        rows = []
        for method in methods:
            eng = CNNEngine(net, method=method)
            us = _time(eng.jit_forward(fuse=False), params, x, iters=iters)
            row = {
                "method": method.value,
                "unfused": {"us_per_call": us, "fps": batch / (us / 1e6)},
            }
            groups = fusion_summary(eng.plan(True))
            if groups:
                us_f = _time(eng.jit_forward(fuse=True), params, x,
                             iters=iters)
                row["fused"] = {"us_per_call": us_f,
                                "fps": batch / (us_f / 1e6)}
                row["fused_speedup"] = us / us_f
                row["fused_groups"] = ["+".join(g) for g in groups]
                # executed chain geometry (group depth + the band the
                # Pallas cell resolves) — carried into the CI trend table
                row["fused_geometry"] = eng.fusion_report()
            rows.append(row)
        out["networks"][name] = {"rows": rows,
                                 "input_shape": list(net.input_shape)}
    return out
