"""Batched CNN serving bench — ``CNNServer`` throughput and latency at
request-batch sizes 1/8/16 (the paper's §6.2 deployment scenario:
forward-only classification of incoming frames, batches of 16).

Each row drives a ``CNNServer`` over the engine's batch-bucketed jit
cache: a warm-up drain compiles the bucket outside the measured window,
then ``requests`` frames are submitted and served in dynamic batches of
``max_batch``, recording throughput (requests per second of server busy
time) and p50/p95 submit→done latency.  ``add_serving_rows`` grafts the
sweep into a ``BENCH_network.json`` dict (under each network's
``serving`` key) so the CI trend gate (``tools/bench_compare.py``)
tracks serving-scale numbers alongside the per-call ladder.
"""
from __future__ import annotations

from typing import Iterable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import CNNEngine
from repro.core.methods import Method
from repro.core.netdefs import NETWORKS
from repro.serving.cnn import CNNServer, ImageRequest
from repro.serving.degrade import DegradeController, default_ladder

DEFAULT_BATCHES: Tuple[int, ...] = (1, 8, 16)
DEFAULT_REQUESTS = 16
_METHOD = Method.ADVANCED_SIMD_8  # the ladder's fastest rung serves
OVERLOAD_BATCH = 8        # max_batch for the overload/degraded-mode row
OVERLOAD_REQUESTS = 64    # burst size (queue bound admits a quarter)


def bench_network(name: str, batches: Iterable[int] = DEFAULT_BATCHES,
                  requests: int = DEFAULT_REQUESTS, fuse: bool = True):
    """Serving rows for one network: one dict per max_batch setting."""
    net = NETWORKS[name]()
    eng = CNNEngine(net, method=_METHOD, fuse_pool=fuse)
    params = eng.init(jax.random.PRNGKey(0))
    n_imgs = min(requests, 32)
    imgs = np.asarray(jax.random.normal(
        jax.random.PRNGKey(1), (n_imgs, *net.input_shape), jnp.float32))
    rows = []
    rid = 0
    for b in batches:
        srv = CNNServer(eng, params, max_batch=b, max_delay_s=0.0)
        # warm-up outside the clock: one full batch compiles bucket b,
        # and when the measured drain ends on a ragged tail
        # (requests % b), that tail's bucket is compiled too
        warm_sizes = [b] + ([requests % b] if requests % b else [])
        for size in warm_sizes:
            for _ in range(size):
                srv.submit(ImageRequest(rid=rid, image=imgs[rid % n_imgs]))
                rid += 1
            srv.run_until_drained()
        srv.reset_stats()
        for _ in range(requests):
            srv.submit(ImageRequest(rid=rid, image=imgs[rid % n_imgs]))
            rid += 1
        srv.run_until_drained()
        s = srv.stats()
        rows.append({
            "batch": b,
            "requests": requests,
            "throughput_rps": s["throughput_rps"],
            "p50_us": s["p50_latency_us"],
            "p95_us": s["p95_latency_us"],
            "mean_batch": s["mean_batch"],
        })
    return rows


def bench_overload(name: str, *, max_batch: int = OVERLOAD_BATCH,
                   requests: int = OVERLOAD_REQUESTS) -> dict:
    """One degraded-mode row: a scripted overload burst against a
    queue-bounded server wearing the degradation ladder.

    The burst submits ``requests`` frames into a queue capped at
    ``4 * max_batch`` — the overflow is rejected at admission (typed
    sheds, counted) — and the degradation controller (pressure
    threshold ``max_batch``, single-observation trigger: this row
    measures the degraded steady state, not the hysteresis, which the
    tier-1 tests cover) walks the server down at least one
    ``CNNEngine.switch_verified``-blessed rung while draining.  The row
    records the shed/degraded counters next to the usual latency and
    throughput numbers; the downgrade recompile lands inside the
    measured window deliberately — that is the cost overload actually
    pays."""
    net = NETWORKS[name]()
    eng = CNNEngine(net, method=_METHOD, fuse_pool=True)
    params = eng.init(jax.random.PRNGKey(0))
    n_imgs = 32
    imgs = np.asarray(jax.random.normal(
        jax.random.PRNGKey(1), (n_imgs, *net.input_shape), jnp.float32))
    # two rungs only: one honest downgrade, not a walk to the floor
    ladder = default_ladder(_METHOD, fuse=True)[:2]
    ctl = DegradeController(ladder, queue_high=max_batch, degrade_after=1,
                            recover_after=10 ** 9, cooldown=0)
    srv = CNNServer(eng, params, max_batch=max_batch, max_delay_s=0.0,
                    max_queue=4 * max_batch, degrade=ctl)
    rid = 0
    for _ in range(max_batch):  # warm the primary bucket off the clock
        srv.submit(ImageRequest(rid=rid, image=imgs[rid % n_imgs]))
        rid += 1
    srv.run_until_drained()
    srv.reset_stats()
    for _ in range(requests):
        srv.submit(ImageRequest(rid=rid, image=imgs[rid % n_imgs]))
        rid += 1
    srv.run_until_drained()
    s = srv.stats()
    return {
        "mode": "degraded",
        "batch": max_batch,
        "requests": requests,
        "served": s["served"],
        "rejected": s["rejected"],
        "shed": s["shed"],
        "degraded": s["degraded"],
        "final_method": eng.method.value,
        "throughput_rps": s.get("throughput_rps", 0.0),
        "p50_us": s.get("p50_latency_us", 0.0),
        "p95_us": s.get("p95_latency_us", 0.0),
        "mean_batch": s["mean_batch"],
    }


def add_serving_rows(data: dict, nets: Iterable[str],
                     batches: Iterable[int] = DEFAULT_BATCHES,
                     requests: int = DEFAULT_REQUESTS,
                     overload: bool = True) -> dict:
    """Graft serving rows into a ``run_json`` bench dict (in place).

    Rows land under ``networks[name]["serving"]`` and the sweep config
    under ``serving_config`` — ``bench_compare`` resets the serving
    baseline (rows report as ``new``) when the config changes, mirroring
    the top-level batch/iters/backend handling.  ``overload`` appends
    the degraded-mode row (``bench_overload``) per network, flattened by
    the trend gate as variant ``batchN-degraded``."""
    batches = tuple(batches)
    data["serving_config"] = {"batches": list(batches),
                              "requests": requests,
                              "method": _METHOD.value, "fused": True}
    if overload:
        data["serving_config"]["overload"] = {
            "batch": OVERLOAD_BATCH, "requests": OVERLOAD_REQUESTS}
    for name in nets:
        rows = bench_network(name, batches=batches, requests=requests)
        if overload:
            rows.append(bench_overload(name))
        data.setdefault("networks", {}).setdefault(name, {})["serving"] = rows
    return data


def run(nets=("lenet5", "cifar10"), batches=DEFAULT_BATCHES,
        requests=DEFAULT_REQUESTS):
    """CSV-harness rows (``name,us_per_call,derived``): p50 latency as
    the headline number, throughput/p95 derived."""
    out = []
    for name in nets:
        for row in bench_network(name, batches=batches, requests=requests):
            out.append({
                "bench": f"cnn_serving/{name}/batch{row['batch']}",
                "us_per_call": row["p50_us"],
                "derived": (f"rps={row['throughput_rps']:.1f} "
                            f"p95_us={row['p95_us']:.0f} "
                            f"mean_batch={row['mean_batch']:.1f}"),
            })
        orow = bench_overload(name)
        out.append({
            "bench": f"cnn_serving/{name}/overload",
            "us_per_call": orow["p50_us"],
            "derived": (f"rps={orow['throughput_rps']:.1f} "
                        f"served={orow['served']} shed={orow['shed']} "
                        f"degraded={orow['degraded']} "
                        f"final={orow['final_method']}"),
        })
    return out
