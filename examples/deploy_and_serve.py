"""End-to-end serving driver (deliverable b): serve a small model with
batched requests through the continuous-batching engine.

Uses a reduced gemma2 (local/global attention, softcaps — the full feature
set) and pushes 8 concurrent requests through 4 slots, demonstrating
prefill-into-slot, batched decode, and slot reuse.

Run:  PYTHONPATH=src python examples/deploy_and_serve.py
"""
import time

import jax
import numpy as np

from repro.core.config import get_arch
from repro.models.registry import get_model
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = get_arch("gemma2-2b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"[serve] model={cfg.name}(reduced) params={n_params/1e6:.1f}M "
          f"slots=4 max_len=128")

    eng = ServingEngine(model, params, max_batch=4, max_len=128)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for rid in range(8):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(4, 16))).tolist()
        eng.submit(Request(rid, prompt,
                           max_new_tokens=int(rng.integers(8, 20)),
                           temperature=0.0 if rid % 2 == 0 else 0.8))
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in done.values())
    print(f"[serve] {len(done)} requests, {toks} tokens, {dt:.1f}s "
          f"({toks/dt:.1f} tok/s on 1 CPU core)")
    for rid in sorted(done):
        print(f"  req {rid}: {done[rid]}")


if __name__ == "__main__":
    main()
