"""Quickstart — the paper's core scenario end-to-end.

1. "Train" LeNet-5 server-side (random init stands in for Caffe training;
   the deploy pipeline is identical), convert + save the deployable model.
2. Load it device-side and run the forward path over a batch of 16 frames
   (paper §6.2) under every execution method of the ladder.
3. Print the per-method runtime and speedup over the sequential reference —
   a miniature of the paper's Table 3.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.core.deploy import save_model, load_model
from repro.core.engine import CNNEngine
from repro.core.methods import Method, LADDER
from repro.core.netdefs import NETWORKS


def main():
    # -- train side -----------------------------------------------------------
    net = NETWORKS["lenet5"]()
    engine = CNNEngine(net)
    params = engine.init(jax.random.PRNGKey(0))
    path = tempfile.mkdtemp(prefix="cnndroid_model_")
    save_model(path, net, params, {"trained_with": "examples/quickstart.py"})
    print(f"[deploy] saved {net.name} -> {path}")

    # -- device side ------------------------------------------------------------
    net2, params2, extra = load_model(path)
    print(f"[deploy] loaded {net2.name} (extra={extra})")
    x = jax.random.normal(jax.random.PRNGKey(1), (16, *net2.input_shape),
                          jnp.float32)  # batch of 16 frames, paper §6.2

    print(f"\n{'method':20s} {'ms/batch':>10s} {'speedup':>9s}  (vs §4.1 sequential)")
    base = None
    for method in LADDER:
        eng = CNNEngine(net2, method=method)
        fn = eng.jit_forward()
        jax.block_until_ready(fn(params2, x))
        t0 = time.perf_counter()
        for _ in range(5):
            out = fn(params2, x)
        jax.block_until_ready(out)
        ms = (time.perf_counter() - t0) / 5 * 1e3
        base = base or ms
        print(f"{method.value:20s} {ms:10.2f} {base/ms:8.2f}x")
    probs = out
    print(f"\npredictions: {jnp.argmax(probs, -1).tolist()}")
    print("(speedups are XLA:CPU; the ladder ordering is the paper's "
          "Table 3 reproduction target — see EXPERIMENTS.md)")


if __name__ == "__main__":
    main()
