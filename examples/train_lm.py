"""End-to-end training driver: train a small LM for a few hundred steps on
a synthetic Markov corpus whose entropy floor is known in closed form, then
checkpoint and reload.

The model is a reduced starcoder2 (sliding-window attention + plain-gelu
MLP).  CE should drop from ~ln(V) toward the Markov entropy floor.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import tempfile

from repro.launch.train import main as train_main
from repro.train.checkpoint import load_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    result = train_main([
        "--arch", "starcoder2-15b", "--reduced",
        "--steps", str(args.steps), "--batch", "8", "--seq", "128",
        "--lr", "3e-3", "--log-every", "20", "--ckpt", ckpt,
    ])
    first = result["history"][0][1]
    last = result["history"][-1][1]
    floor = result["floor"]
    print(f"\n[train_lm] ce {first:.3f} -> {last:.3f} "
          f"(floor {floor:.3f}); improvement {first-last:.3f} nats")
    params, opt, step, extra = load_checkpoint(ckpt)
    print(f"[train_lm] checkpoint reloaded: step={step} arch={extra['arch']}")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
